"""Scheme-registry property suite (ISSUE 4 satellite).

Three contracts every registered family must honor:

  * CONSTRUCTION — the family constructs at ragged sizes (n not a
    multiple of 8) for every s it declares legal there, and the
    resulting GradientCode round-trips its own name through the
    registry (elastic with_workers depends on that).
  * DECODE EQUIVALENCE — for every (family, decoder) pair the registry
    declares compatible, the batched DecodeEngine weights equal the
    scalar decoding.* oracles per mask.
  * ERRORS — unknown schemes and invalid (k, n, s) raise actionable
    messages (what exists, what is legal, how to register).
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import codes as C
from repro.core import decoding as D
from repro.core import registry as R
from repro.core.engine import DecodeEngine

RAGGED_NS = (7, 13, 26)         # n not a multiple of 8


def _pick_s(fam, k, n, want=3):
    """A legal s for this family at (k, n), as close to `want` as
    possible (FRC needs s | k, s-regular needs k*s even, ...)."""
    legal = fam.legal_s(k, n, hi=min(k, 8))
    assert legal, f"{fam.name} has no legal s at (k={k}, n={n})"
    return min(legal, key=lambda s: (abs(s - want), s))


# ==========================================================================
# construction at ragged sizes
# ==========================================================================


@pytest.mark.parametrize("n", RAGGED_NS)
@pytest.mark.parametrize("fam", R.families(), ids=lambda f: f.name)
def test_every_family_constructs_ragged(fam, n):
    s = _pick_s(fam, n, n)
    code = fam.make(k=n, n=n, s=s, seed=0)
    assert code.G.shape == (n, n)
    assert code.name == fam.name        # with_workers rebuilds by name
    assert np.isfinite(code.G).all()
    # determinism given the seed
    again = fam.make(k=n, n=n, s=s, seed=0)
    assert np.array_equal(code.G, again.G)
    # the ELL packing (kernel-facing view) holds at ragged sizes too
    idx, val = code.ell()
    dense = np.zeros_like(code.G)
    for i in range(code.k):
        np.add.at(dense[i], idx[i], val[i])
    assert_allclose(dense, code.G)


def test_registry_names_cover_code_registry():
    """The declarative layer and the raw constructor table agree."""
    assert set(R.names()) == set(C.CODE_REGISTRY)


def test_make_code_delegates_to_registry():
    a = C.make_code("sbm", k=20, n=20, s=4, seed=9, blocks=2)
    b = R.make("sbm", k=20, n=20, s=4, seed=9, blocks=2)
    assert np.array_equal(a.G, b.G)


def test_randomized_declarations():
    assert set(R.randomized_schemes()) == {"bgc", "rbgc", "sregular",
                                           "sbm", "expander"}


# ==========================================================================
# new families: structural properties
# ==========================================================================


@pytest.mark.parametrize("k,n,s", [(13, 13, 4), (26, 26, 5), (40, 30, 6)])
def test_expander_biregular_at_ragged_sizes(k, n, s):
    code = R.make("expander", k=k, n=n, s=s, seed=1)
    assert np.all(code.col_degrees == s)            # workers: exactly s
    lo, hi = (n * s) // k, -(-(n * s) // k)
    assert code.row_degrees.min() >= lo             # tasks: ns/k +- 1
    assert code.row_degrees.max() <= hi


def test_sbm_intra_inter_densities():
    code = R.make("sbm", k=64, n=64, s=8, seed=2, blocks=4, intra=0.9)
    member_t = C.block_ids(64, 4)
    member_w = C.block_ids(64, 4)
    same = member_t[:, None] == member_w[None, :]
    assert code.G[same].mean() > 5 * code.G[~same].mean()
    # expected column degree calibrated to s
    assert abs(code.col_degrees.mean() - 8) < 2.0


def test_sbm_single_block_degenerates_to_bernoulli():
    code = R.make("sbm", k=50, n=50, s=5, seed=3, blocks=1)
    assert abs(code.density - 5 / 50) < 0.05


@pytest.mark.parametrize("k,s,blocks,intra", [(32, 10, 8, 0.9),
                                              (100, 10, 8, 0.95),
                                              (64, 8, 4, 0.1)])
def test_sbm_degree_calibrated_even_when_a_side_saturates(k, s, blocks,
                                                          intra):
    """E[column degree] == s even when intra*s exceeds the own-cluster
    task count (the saturated side spills to the other side instead of
    dropping mass — regression: s=10, blocks=8 gave mean degree 5)."""
    degs = [R.make("sbm", k=k, n=k, s=s, seed=t, blocks=blocks,
                   intra=intra).col_degrees.mean() for t in range(8)]
    assert abs(np.mean(degs) - s) < 0.35 * np.sqrt(s)


def test_with_workers_preserves_family_params():
    """Elastic rebuild keeps the VARIANT, not the family defaults
    (regression: an sbm intra=0.1 code silently became intra=0.7)."""
    code = R.make("sbm", k=64, n=64, s=6, seed=0, blocks=2, intra=0.1)
    assert dict(code.params) == {"blocks": 2, "intra": 0.1}
    rng = np.random.default_rng(1)
    smaller = code.with_workers(32, rng)
    assert smaller.n == 32 and smaller.name == "sbm"
    assert dict(smaller.params) == {"blocks": 2, "intra": 0.1}
    # and the rebuilt support really is the low-intra variant
    expect = R.make("sbm", k=32, n=32, s=6,
                    rng=np.random.default_rng(1), blocks=2, intra=0.1)
    assert np.array_equal(smaller.G, expect.G)


def test_trainer_forwards_code_params():
    """CodedTrainConfig.code_params reach the constructor on build and
    survive elastic re-coding (the rebuild goes through fam.make with
    the same params)."""
    import types

    import jax
    import jax.numpy as jnp

    from repro.training import CodedTrainConfig, CodedTrainer

    class ToyModel:
        cfg = types.SimpleNamespace(vocab=32, schedule="cosine")

        def init(self, key):
            return {"w": jax.random.normal(key, (16,)) * 0.1}

        def loss_fn(self, params, batch):
            x = batch["tokens"].astype(jnp.float32)
            row = (x @ params["w"]) ** 2
            wloss = (row * batch["loss_weight"].astype(jnp.float32)).sum()
            return wloss, {"loss": wloss, "mean_ce": row.mean()}

    tr = CodedTrainer(ToyModel(), CodedTrainConfig(
        code="sbm", n_workers=16, s=4, seq_len=16,
        code_params={"blocks": 2, "intra": 0.1}))
    assert dict(tr.code.params) == {"blocks": 2, "intra": 0.1}
    tr._build_code(12)                           # elastic rebuild
    assert tr.code.n == 12
    assert dict(tr.code.params) == {"blocks": 2, "intra": 0.1}


@pytest.mark.parametrize("k,n", [(2, 8), (8, 2), (3, 3)])
def test_sbm_more_blocks_than_tasks_or_workers(k, n):
    """blocks > min(k, n) must clip on BOTH sides, not index past the
    smaller partition (regression: k=2, n=8, blocks=4 raised)."""
    code = R.make("sbm", k=k, n=n, s=min(2, k), seed=0, blocks=4)
    assert code.G.shape == (k, n)
    assert np.isfinite(code.G).all()


# ==========================================================================
# batched engine decode == scalar decode, per declared (family, decoder)
# ==========================================================================


def _scalar_weights(G, mask, decoder, iters):
    if decoder == "algorithmic":
        return D.decode_weights(G, mask, method=decoder, iters=iters)
    return D.decode_weights(G, mask, method=decoder)


@pytest.mark.parametrize("fam", R.families(), ids=lambda f: f.name)
def test_batched_decode_matches_scalar_per_declared_decoder(fam):
    n = 13                                  # ragged on purpose
    s = _pick_s(fam, n, n)
    code = fam.make(k=n, n=n, s=s, seed=4)
    rng = np.random.default_rng(5)
    masks = rng.random((8, n)) < 0.7
    masks[0] = True                         # no stragglers
    masks[1] = False                        # all stragglers
    # pinv opt-in: the scalar decoding.* oracles ARE the pinv path
    eng = DecodeEngine(code, iters=4, optimal_impl="pinv")
    for decoder in fam.decoders:
        res = eng.decode_batch(masks, decoder)
        assert res.weights.shape == (8, n)
        assert np.all(np.isfinite(res.errors))
        for b, mask in enumerate(masks):
            want = _scalar_weights(code.G, mask, decoder, iters=4)
            assert_allclose(res.weights[b], want, atol=1e-6,
                            err_msg=f"{fam.name}/{decoder} mask {b}")


@pytest.mark.parametrize("fam_name", ["sbm", "expander"])
def test_gram_optimal_errors_match_pinv(fam_name):
    """The masked-Gram least-squares path (the new families' fast
    decoder) agrees with the exact pinv path on decode errors."""
    fam = R.get(fam_name)
    code = fam.make(k=26, n=26, s=4, seed=6)
    rng = np.random.default_rng(7)
    masks = rng.random((12, 26)) < 0.6
    r_pinv = DecodeEngine(code, optimal_impl="pinv").decode_batch(
        masks, "optimal")
    r_gram = DecodeEngine(code, optimal_impl="gram").decode_batch(
        masks, "optimal")
    assert_allclose(r_gram.errors, r_pinv.errors, atol=1e-6, rtol=1e-6)
    r_int = DecodeEngine(code, backend="pallas_interpret").decode_batch(
        masks, "optimal")
    # 0/1 supports: the kernel's fp32 masked Gram is exact, so the
    # interpret backend reproduces the numpy gram path bit-for-bit
    assert_allclose(r_int.weights, r_gram.weights, atol=0)


# ==========================================================================
# actionable errors
# ==========================================================================


def test_unknown_scheme_error_is_actionable():
    with pytest.raises(KeyError) as ei:
        R.get("fountain")
    msg = str(ei.value)
    assert "fountain" in msg
    assert "bgc" in msg                     # lists what IS registered
    assert "register" in msg                # says how to add one


def test_unknown_scheme_error_reaches_every_layer():
    from repro.sim.traces import make_trace
    from repro.training import CodedTrainConfig, CodedTrainer

    with pytest.raises(KeyError, match="fountain"):
        C.make_code("fountain", k=8, n=8, s=2)
    trace = make_trace("pareto", steps=4, n=8, seed=0)
    from repro.sim.cluster import ClusterSim
    with pytest.raises(KeyError, match="fountain"):
        ClusterSim("fountain", trace, "deadline", s=2)
    with pytest.raises(KeyError, match="fountain"):
        CodedTrainer(object(), CodedTrainConfig(code="fountain"))


def test_illegal_params_error_names_legal_s():
    with pytest.raises(ValueError) as ei:
        R.make("frc", k=10, n=10, s=3)      # 3 does not divide 10
    msg = str(ei.value)
    assert "legal s" in msg and "frc" in msg


def test_incompatible_decoder_rejected_by_trainer_and_sim():
    fam = R.get("frc")
    narrow = R.CodeFamily(
        name="frc_onestep_only", constructor=fam.constructor,
        decoders=("onestep",), adversary="block", validate=fam.validate)
    R.register(narrow)
    try:
        from repro.sim.cluster import ClusterSim
        from repro.sim.traces import make_trace
        trace = make_trace("pareto", steps=4, n=8, seed=0)
        with pytest.raises(ValueError, match="onestep"):
            ClusterSim("frc_onestep_only", trace, "deadline",
                       decoder="optimal", s=2)
        from repro.core.simulate import monte_carlo_error
        with pytest.raises(ValueError, match="onestep"):
            monte_carlo_error("frc_onestep_only", k=8, n=8, s=2, delta=0.2,
                              trials=4, decoder="optimal")
    finally:
        R._REGISTRY.pop("frc_onestep_only", None)


def test_register_rejects_duplicates_and_bad_records():
    fam = R.get("bgc")
    with pytest.raises(ValueError, match="already registered"):
        R.register(fam)
    with pytest.raises(ValueError, match="unknown"):
        R.CodeFamily(name="x", constructor=fam.constructor,
                     decoders=("onestep", "magic"))
    with pytest.raises(ValueError, match="adversary"):
        R.CodeFamily(name="x", constructor=fam.constructor,
                     adversary="quantum")
