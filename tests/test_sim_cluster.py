"""ClusterSim subsystem tests: traces, sync policies, the one-batched-
decode-per-run invariant, frontiers, and the wallclock_summary
aggregate (sole successor of the removed runtime.latency wrapper)."""

import numpy as np
import pytest

from repro.core import codes as C
from repro.core import decoding as D
from repro.runtime import (BimodalStragglers, DeadlineStragglers,
                           FixedFractionStragglers)
from repro.sim import (AdaptiveDeadline, BackupPolicy, ClusterSim,
                       DeadlinePolicy, LatencyTrace, WaitForAll, make_policy,
                       make_trace, pareto_front, sweep_frontier,
                       time_to_target_error, trace_from_model,
                       wallclock_summary)


# ------------------------------ traces --------------------------------------

def test_trace_from_latency_model_matches_model_rows():
    m = DeadlineStragglers(seed=3, tail_scale=0.4)
    tr = trace_from_model(m, steps=7, n=16)
    assert (tr.steps, tr.n) == (7, 16)
    for t in range(7):
        np.testing.assert_array_equal(tr.latencies[t], m.latencies(t, 16))


def test_trace_from_mask_only_model_is_two_point():
    m = FixedFractionStragglers(delta=0.25, seed=0)
    tr = trace_from_model(m, steps=5, n=16, base=1.0, slow=3.0)
    assert set(np.unique(tr.latencies)) == {1.0, 3.0}
    for t in range(5):
        np.testing.assert_array_equal(tr.latencies[t] == 1.0,
                                      m.sample(t, 16))


def test_trace_scaled_window_tile():
    tr = make_trace("bimodal", steps=6, n=8, seed=1)
    assert np.allclose(tr.scaled(2.0).latencies, 2.0 * tr.latencies)
    assert tr.window(2, 5).steps == 3
    tiled = tr.tile(15)
    assert tiled.steps == 15
    np.testing.assert_array_equal(tiled.latencies[6], tr.latencies[0])


def test_trace_json_replay_roundtrip(tmp_path):
    tr = make_trace("pareto", steps=4, n=6, seed=2, tail_scale=0.3)
    p = tr.save(tmp_path / "trace.json")
    back = LatencyTrace.load(p)
    np.testing.assert_allclose(back.latencies, tr.latencies)
    replayed = make_trace("replay", steps=10, path=p)
    assert replayed.steps == 10
    np.testing.assert_allclose(replayed.latencies[4], tr.latencies[0])


def test_trace_validation():
    with pytest.raises(ValueError):
        LatencyTrace(np.ones(5))          # not 2-D
    with pytest.raises(ValueError):
        LatencyTrace(-np.ones((2, 3)))    # negative latency
    with pytest.raises(ValueError):
        make_trace("replay")              # replay needs path
    with pytest.raises(ValueError):
        make_trace("pareto", steps=0, n=4)


# ------------------------------ policies ------------------------------------

def _trace(steps=50, n=32, seed=0):
    return make_trace("pareto", steps=steps, n=n, seed=seed, tail_scale=0.4)


@pytest.mark.parametrize("policy", [WaitForAll(), DeadlinePolicy(1.5),
                                    BackupPolicy(0.9),
                                    AdaptiveDeadline(target=0.15)])
def test_policy_apply_equals_step_loop(policy):
    """The vectorized apply must equal the incremental step() path the
    trainer uses (same masks, same times, any policy state threading)."""
    lat = _trace().latencies
    masks_v, times_v, _ = policy.apply(lat)
    state = None
    for t in range(lat.shape[0]):
        mask, tt, state = policy.step(lat[t], state)
        np.testing.assert_array_equal(masks_v[t], mask)
        assert times_v[t] == pytest.approx(tt, abs=0)


def test_sync_policy_no_stragglers_max_time():
    lat = _trace().latencies
    masks, times, _ = WaitForAll().apply(lat)
    assert masks.all()
    np.testing.assert_allclose(times, lat.max(axis=1))


def test_deadline_policy_semantics():
    lat = _trace().latencies
    masks, times, _ = DeadlinePolicy(deadline=1.6).apply(lat)
    np.testing.assert_array_equal(masks, lat <= 1.6)
    assert times.max() <= 1.6 + 1e-12


def test_backup_policy_waits_for_quantile():
    lat = _trace().latencies
    masks, times, _ = BackupPolicy(quantile=0.9).apply(lat)
    # at least 90% of workers report every step, and the step ends at
    # the cut time
    assert (masks.mean(axis=1) >= 0.9 - 1e-12).all()
    np.testing.assert_allclose(times,
                               np.quantile(lat, 0.9, axis=1,
                                           method="higher"))


def test_adaptive_deadline_steers_to_target():
    """On a stationary trace the controller's straggler fraction
    converges to the target band."""
    target = 0.15
    pol = AdaptiveDeadline(target=target, gain=0.5, d0=10.0)
    lat = _trace(steps=300, n=64).latencies
    masks, _, extras = pol.apply(lat)
    frac = 1.0 - masks.mean(axis=1)
    assert abs(frac[-100:].mean() - target) < 0.05
    assert extras["deadlines"].shape == (300,)
    # started way above the tail -> the controller tightened
    assert extras["deadlines"][-1] < 10.0


def test_make_policy_registry():
    assert isinstance(make_policy("sync"), WaitForAll)
    assert isinstance(make_policy("adaptive", target=0.2), AdaptiveDeadline)
    p = DeadlinePolicy(2.0)
    assert make_policy(p) is p
    with pytest.raises(ValueError):
        make_policy("nope")


# ------------------------------ ClusterSim ----------------------------------

def test_clustersim_exactly_one_batched_decode_per_run():
    """The ISSUE acceptance invariant: a run of S steps performs exactly
    one batched decode — no per-step Python decode loop."""
    code = C.make_code("bgc", k=32, n=32, s=4, rng=np.random.default_rng(0))
    sim = ClusterSim(code, _trace(steps=200, n=32), "deadline", s=4)
    assert sim.engine.batch_calls == 0
    res = sim.run()
    assert sim.engine.batch_calls == 1
    assert res.errors.shape == (200,)


def test_clustersim_errors_match_scalar_decode_loop():
    """Per-step errors from the single batched decode equal the scalar
    per-step decode of each policy mask."""
    code = C.make_code("frc", k=24, n=24, s=4, rng=np.random.default_rng(1))
    tr = _trace(steps=40, n=24, seed=5)
    for decoder in ("onestep", "optimal"):
        res = ClusterSim(code, tr, DeadlinePolicy(1.6), decoder=decoder,
                         s=4).run()
        for t in (0, 7, 39):
            mask = tr.latencies[t] <= 1.6
            A = code.G[:, mask]
            if decoder == "onestep":
                want = D.err1(A, D.default_rho(code.k, int(mask.sum()), 4))
            else:
                want = D.err(A)
            assert res.errors[t] == pytest.approx(want / code.k,
                                                  rel=1e-8, abs=1e-10)


def test_clustersim_result_summary_stats():
    code = C.make_code("bgc", k=16, n=16, s=4, rng=np.random.default_rng(2))
    res = ClusterSim(code, _trace(steps=30, n=16), "deadline", s=4).run()
    assert res.total_time == pytest.approx(res.step_times.sum())
    assert res.steps == 30
    s = res.summary()
    assert s["policy"] == "deadline" and s["mean_error"] >= 0.0
    assert res.worst_stragglers >= res.mean_stragglers - 1e-9


def test_clustersim_trace_code_mismatch_raises():
    code = C.make_code("bgc", k=16, n=16, s=4, rng=np.random.default_rng(0))
    with pytest.raises(ValueError):
        ClusterSim(code, _trace(n=32), "sync")


# --------------------- staleness pipelining (docs §10) ----------------------

def test_clustersim_staleness_zero_is_synchronous():
    """staleness=0 keeps the synchronous semantics bit-for-bit, and a
    synchronous decode cost is a barrier every step pays."""
    code = C.make_code("bgc", k=24, n=24, s=4, rng=np.random.default_rng(3))
    tr = _trace(steps=40, n=24, seed=7)
    base = ClusterSim(code, tr, "deadline", s=4).run()
    same = ClusterSim(code, tr, "deadline", s=4, staleness=0).run()
    np.testing.assert_array_equal(same.errors, base.errors)
    np.testing.assert_array_equal(same.step_times, base.step_times)
    cost = ClusterSim(code, tr, "deadline", s=4, decode_cost=0.25).run()
    np.testing.assert_allclose(cost.step_times, base.step_times + 0.25)


def test_clustersim_staleness_one_semantics():
    """Step t applies the weights decoded from step t-1's mask, re-masked
    by step t's stragglers; step 0 warm-starts from an all-alive decode.
    Still exactly ONE batched decode per run."""
    code = C.make_code("bgc", k=20, n=20, s=4, rng=np.random.default_rng(4))
    tr = _trace(steps=30, n=20, seed=9)
    sim = ClusterSim(code, tr, DeadlinePolicy(1.6), s=4, staleness=1)
    assert sim.engine.batch_calls == 0
    res = sim.run()
    assert sim.engine.batch_calls == 1
    masks, _, _ = DeadlinePolicy(1.6).apply(tr.latencies)
    eng = ClusterSim(code, tr, DeadlinePolicy(1.6), s=4).engine
    for t in (0, 1, 17, 29):
        prev = np.ones(20, bool) if t == 0 else masks[t - 1]
        w = eng.decode_batch(prev[None], "onestep").weights[0] * masks[t]
        want = float(D.err_batch(code.G, w[None])[0]) / code.k
        assert res.errors[t] == pytest.approx(want, rel=1e-10, abs=1e-12)


def test_clustersim_staleness_overlap_hides_decode_cost():
    """With pipelining the decode leaves the critical path: each step
    costs max(compute, decode) instead of compute + decode."""
    code = C.make_code("bgc", k=16, n=16, s=4, rng=np.random.default_rng(5))
    tr = _trace(steps=25, n=16, seed=11)
    sync = ClusterSim(code, tr, "deadline", s=4, decode_cost=0.5).run()
    pipe = ClusterSim(code, tr, "deadline", s=4, decode_cost=0.5,
                      staleness=1).run()
    np.testing.assert_allclose(pipe.step_times,
                               np.maximum(sync.step_times - 0.5, 0.5))
    assert pipe.total_time < sync.total_time
    with pytest.raises(ValueError):
        ClusterSim(code, tr, "deadline", s=4, staleness=-1)


# ------------------------------ frontier ------------------------------------

def test_sweep_frontier_grid_and_pareto():
    tr = _trace(steps=60, n=24, seed=3)
    pts = sweep_frontier(("frc", "bgc", "cyclic"),
                         ("sync", "deadline", "backup"), tr, s=4)
    assert len(pts) == 9
    assert {(p.scheme, p.policy) for p in pts} == {
        (s, p) for s in ("frc", "bgc", "cyclic")
        for p in ("sync", "deadline", "backup")}
    front = pareto_front(pts)
    assert front
    # non-domination: no point beats a front point on both axes
    for f in front:
        for p in pts:
            assert not (p.mean_step_time < f.mean_step_time
                        and p.mean_error < f.mean_error)
    # sync never decodes with error under the optimal... use onestep:
    # sync cells carry the largest step time in their scheme
    for s in ("frc", "bgc", "cyclic"):
        cell = {p.policy: p for p in pts if p.scheme == s}
        assert cell["sync"].mean_step_time >= cell["deadline"].mean_step_time
        assert cell["sync"].mean_stragglers == 0.0


def test_time_to_target_inflates_with_error():
    code = C.make_code("bgc", k=16, n=16, s=2, rng=np.random.default_rng(0))
    res = ClusterSim(code, _trace(steps=20, n=16), "deadline", s=2).run()
    assert time_to_target_error(res) >= res.total_time
    # saturates rather than blowing up when error ~ 1
    res.errors[:] = 2.0
    assert time_to_target_error(res) == pytest.approx(100.0 * res.total_time)


# --------------------- wallclock_summary aggregate --------------------------

def test_wallclock_summary_semantics():
    """The aggregate summary (which absorbed the removed
    runtime.latency.simulate_wallclock): deadline masks on the unscaled
    trace, step times on the scaled one; sync/backup report all-ones
    masks (the documented legacy quirk)."""
    tr = trace_from_model(DeadlineStragglers(seed=11, tail_scale=0.4),
                          steps=40, n=24)
    for scale in (1.0, 2.5):
        got = wallclock_summary(tr, policy="deadline", deadline=1.5,
                                compute_scale=scale)
        masks = tr.latencies <= 1.5
        times = np.minimum(1.5 * scale, tr.latencies.max(axis=1) * scale)
        assert got["total_time"] == pytest.approx(times.sum(), rel=1e-12)
        assert got["mean_stragglers"] == pytest.approx(
            (~masks).sum(1).mean())
        assert got["worst_stragglers"] == int((~masks).sum(1).max())
    for policy in ("sync", "backup"):
        assert wallclock_summary(tr, policy=policy)["mean_stragglers"] == 0.0
    with pytest.raises(ValueError):
        wallclock_summary(tr, policy="nope")


def test_wallclock_summary_bimodal_trade():
    """The headline trade on the bimodal slow-node trace: deadline
    aggregation bounds step time below wait-for-all."""
    tr = trace_from_model(BimodalStragglers(slow_fraction=0.2, seed=0),
                          steps=50, n=32)
    sync = wallclock_summary(tr, policy="sync")
    dead = wallclock_summary(tr, policy="deadline", deadline=1.5)
    assert dead["mean_step_time"] <= 1.5 + 1e-9
    assert sync["mean_step_time"] > dead["mean_step_time"]
    assert dead["mean_stragglers"] > 0


# --------------- training-loop trace hook (co-simulation) -------------------

@pytest.mark.slow
def test_trainer_trace_hook_logs_sim_time():
    from repro import configs as CFG
    from repro.models import build_model
    from repro.optim import OptConfig
    from repro.training import CodedTrainConfig, CodedTrainer

    cfg = CFG.get_config("minicpm-2b", smoke=True)
    model = build_model(cfg)
    tr = make_trace("pareto", steps=5, n=8, seed=0, tail_scale=0.4)
    trainer = CodedTrainer(
        model,
        CodedTrainConfig(code="bgc", n_workers=8, s=2, steps=5, seq_len=16,
                         log_every=1,
                         opt=OptConfig(lr=1e-3, warmup_steps=2,
                                       total_steps=10)),
        trace=tr, sync_policy=DeadlinePolicy(1.6))
    out = trainer.run()
    hist = out["history"]
    assert len(hist) == 5
    masks, times, _ = DeadlinePolicy(1.6).apply(tr.latencies)
    for t, h in enumerate(hist):
        assert h["step_time"] == pytest.approx(times[t])
        assert h["stragglers"] == int((~masks[t]).sum())
    assert hist[-1]["sim_time"] == pytest.approx(times.sum())


def test_trainer_trace_hook_validation():
    from repro import configs as CFG
    from repro.models import build_model
    from repro.training import CodedTrainConfig, CodedTrainer

    cfg = CFG.get_config("minicpm-2b", smoke=True)
    model = build_model(cfg)
    with pytest.raises(ValueError):
        CodedTrainer(model, CodedTrainConfig(n_workers=8),
                     trace=make_trace("pareto", steps=3, n=4, seed=0))
    with pytest.raises(ValueError):
        CodedTrainer(model, CodedTrainConfig(n_workers=8),
                     sync_policy="deadline")
