"""Executable documentation: the README / docs code snippets run in CI.

Scrapes every ```python fence from README.md and docs/*.md and executes
each one in its own subprocess (PYTHONPATH=src, CPU jax, 8 forced host
devices so device-mesh examples exercise a real 8-way world).  A
documented example that stops working fails this suite instead of
silently rotting.

Conventions (documented in docs/benchmarks.md): snippets are
self-contained and seconds-scale; a fence whose first line contains
``no-exec`` is skipped; bash fences are never executed.
"""

import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.platform import subprocess_env

REPO = Path(__file__).resolve().parent.parent

FENCE_RE = re.compile(r"^```python\n(.*?)^```", re.S | re.M)

# every markdown file whose snippets are part of the public docs
DOC_FILES = ("README.md", "DESIGN.md") + tuple(
    f"docs/{p.name}" for p in sorted((REPO / "docs").glob("*.md"))
)


def iter_snippets():
    for rel in DOC_FILES:
        path = REPO / rel
        if not path.exists():
            continue
        text = path.read_text()
        for match in FENCE_RE.finditer(text):
            code = match.group(1)
            stripped = code.strip()
            if not stripped:
                continue
            if "no-exec" in stripped.splitlines()[0]:
                continue
            line = text[: match.start()].count("\n") + 2
            yield pytest.param(code, id=f"{rel}:{line}")


SNIPPETS = list(iter_snippets())


def test_scraper_found_the_documented_examples():
    """Guard the scraper itself: the docs ship a known minimum of
    executable examples (README quickstart-adjacent snippets plus the
    families / adaptive pages).  If this drops, the regex or the docs
    broke — not the examples."""
    assert len(SNIPPETS) >= 5
    ids = {p.id for p in SNIPPETS}
    assert any(i.startswith("README.md") for i in ids)
    assert any(i.startswith("docs/adaptive.md") for i in ids)
    assert any(i.startswith("docs/families.md") for i in ids)


@pytest.mark.slow
@pytest.mark.parametrize("code", SNIPPETS)
def test_doc_snippet_executes(code):
    # override=True: snippets document an exact world (cpu, 8 host
    # devices) and must not inherit a stray XLA_FLAGS from the runner
    env = subprocess_env(platform="cpu", host_devices=8, override=True)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=REPO,
        env=env,
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert proc.returncode == 0, (
        f"documented snippet failed\n--- code ---\n{code}\n"
        f"--- stderr ---\n{proc.stderr[-3000:]}"
    )
