"""Golden regression tests for the Monte-Carlo statistical core.

These pin `monte_carlo_error` means for frc / bgc / cyclic at fixed
seeds so a decoder or engine refactor cannot silently shift the
Fig. 2-4 curves: the sampled masks and decode path are deterministic
given (seed, scheme, params), so the means must reproduce to float
rounding (GOLDEN_RTOL absorbs BLAS reduction-order differences only).

Each pinned cell is also cross-checked against the closed forms in
core/theory.py with an explicit tolerance band sized from the cell's
Monte-Carlo standard error — the pin guards the implementation, the
band guards the statistics.
"""

import functools

import numpy as np
import pytest

from repro.core import theory as T
from repro.core.simulate import monte_carlo_error

SEED = 1234
K = 100
# float-rounding band for the golden pins: the mask sampling and decode
# are bit-deterministic given the seed; only BLAS summation order varies
GOLDEN_RTOL = 1e-6

# (scheme, s, delta, decoder, trials) -> golden mean err/k at SEED
GOLDEN_MEANS = {
    ("frc", 5, 0.1, "onestep", 2000): 0.021362962962962976,
    ("frc", 5, 0.3, "onestep", 2000): 0.08189795918367344,
    ("frc", 5, 0.3, "optimal", 2000): 0.0014500000000000001,
    ("bgc", 10, 0.1, "onestep", 2000): 0.09567327160493827,
    ("bgc", 10, 0.3, "onestep", 2000): 0.12401530612244897,
    ("bgc", 10, 0.3, "optimal", 2000): 0.041239671937050366,
    ("cyclic", 5, 0.1, "onestep", 2000): 0.02121283950617285,
    ("cyclic", 5, 0.3, "onestep", 2000): 0.08228489795918367,
    ("cyclic", 5, 0.3, "optimal", 2000): 0.011826251648357544,
}


@functools.lru_cache(maxsize=None)
def _run(scheme, s, delta, decoder, trials, **kw):
    return monte_carlo_error(scheme, k=K, n=K, s=s, delta=delta,
                             trials=trials, decoder=decoder, seed=SEED, **kw)


@pytest.mark.parametrize("cell,golden", sorted(GOLDEN_MEANS.items()))
def test_golden_mean_pinned(cell, golden):
    scheme, s, delta, decoder, trials = cell
    res = _run(scheme, s, delta, decoder, trials)
    assert res.mean == pytest.approx(golden, rel=GOLDEN_RTOL), (
        f"{cell}: Monte-Carlo mean moved from the pinned golden value — "
        "a decode/engine refactor changed the statistical core, or the "
        "mask sampling stream shifted.  If the change is intentional "
        "(verified against core/theory.py), re-pin GOLDEN_MEANS.")


def test_golden_distribution_shape_pinned():
    """Quantiles/std of one reference cell, pinned alongside the mean —
    catches refactors that preserve the mean but reshape the law."""
    res = _run("frc", 5, 0.3, "onestep", 2000)
    assert res.std == pytest.approx(0.023830504847068164, rel=GOLDEN_RTOL)
    assert res.q05 == pytest.approx(0.04489795918367346, rel=GOLDEN_RTOL)
    assert res.q95 == pytest.approx(0.11877551020408128, rel=GOLDEN_RTOL)
    assert res.p_zero == 0.0


def test_golden_algorithmic_cell_pinned():
    res = _run("bgc", 10, 0.3, "algorithmic", 600, iters=6)
    assert res.mean == pytest.approx(0.0772582992048347, rel=GOLDEN_RTOL)


# --------------------- theory cross-checks (tolerance bands) ----------------

def _band(res, sigmas=4.0):
    """Monte-Carlo band: sigmas * empirical standard error of the mean."""
    return sigmas * res.std / np.sqrt(res.trials)


def test_frc_onestep_matches_thm5_exact():
    for delta in (0.1, 0.3):
        res = _run("frc", 5, delta, "onestep", 2000)
        r = int(round((1 - delta) * K))
        want = T.thm5_expected_err1_frc_exact(K, 5, r) / K
        assert res.mean == pytest.approx(want, abs=_band(res)), delta


def test_frc_optimal_matches_thm6():
    res = _run("frc", 5, 0.3, "optimal", 2000)
    want = T.thm6_expected_err_frc(K, 5, 70) / K
    # err(A) is heavy-tailed (most trials decode exactly); allow 5 SEs
    assert res.mean == pytest.approx(want, abs=_band(res, sigmas=5.0))


def test_bgc_onestep_matches_exact_expectation():
    for delta in (0.1, 0.3):
        res = _run("bgc", 10, delta, "onestep", 2000)
        r = int(round((1 - delta) * K))
        want = T.expected_err1_bgc_exact(K, 10, r) / K
        # bgc also averages code randomness over code_draws=16 draws;
        # the residual code-level variance widens the band
        assert res.mean == pytest.approx(want, rel=0.08), delta


def test_cyclic_onestep_within_frc_neighborhood():
    """No closed form for cyclic in the paper; it is an s-regular
    expander-like code, so its one-step error must sit within the
    Thm-3-style O(delta k / s) scale — the band that pins its curve to
    the right order."""
    for delta in (0.1, 0.3):
        res = _run("cyclic", 5, delta, "onestep", 2000)
        scale = delta / ((1 - delta) * 5)  # (delta k / ((1-d) s)) / k
        assert 0.05 * scale <= res.mean <= 2.0 * scale, delta


def test_decoder_ordering_preserved():
    """optimal <= algorithmic <= onestep on the same cell (Lemma 12
    interpolation) — an engine refactor must not reorder the decoders."""
    one = _run("bgc", 10, 0.3, "onestep", 600)
    alg = _run("bgc", 10, 0.3, "algorithmic", 600, iters=6)
    opt = _run("bgc", 10, 0.3, "optimal", 600)
    assert opt.mean <= alg.mean + 1e-9
    assert alg.mean <= one.mean + 1e-9
