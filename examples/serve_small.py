"""Serving example: batched prefill + continuous-batching decode of a
small model through the ServingEngine (the serve_step the decode-shape
dry-run cells lower).

    PYTHONPATH=src python examples/serve_small.py [--arch rwkv6-3b]

Uses a reduced config of an assigned architecture; rwkv6/recurrentgemma
demonstrate O(1)-state decode (the long_500k family), attention archs the
ring-buffer KV cache.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {model.param_count() / 1e6:.1f}M params, "
          f"{args.slots} slots")

    engine = ServingEngine(model, params, batch_slots=args.slots,
                           cache_len=args.prompt_len + args.max_new + 8)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    t0 = time.time()
    results = engine.serve_queue(reqs)
    dt = time.time() - t0
    total_new = sum(len(v) for v in results.values())
    for rid in sorted(results)[:5]:
        print(f"  req {rid}: {results[rid]}")
    print(f"\n{len(results)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s CPU, reduced config)")

    # determinism check: same prompt -> same continuation
    again = engine.serve_queue([Request(rid=99, prompt=reqs[0].prompt,
                                        max_new_tokens=args.max_new)])
    assert again[99] == results[0], "greedy decode must be deterministic"
    print("determinism check OK")


if __name__ == "__main__":
    main()
