"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
coded data parallelism, checkpoint/restart, and a mid-run elastic
worker-failure event.

    PYTHONPATH=src python examples/coded_training_e2e.py \
        [--steps 300] [--arch starcoder2-7b] [--d-model 512] [--layers 8]

The model is the assigned architecture's family at ~100M scale (full
configs are exercised via the dry-run; this is the runnable-on-CPU
driver).  Demonstrates, in one run:

  * BGC code construction + per-step decode-weight computation,
  * decode-as-loss-reweighting training (docs/architecture.md §2.1),
  * deadline stragglers (Pareto tail) absorbed as decode error,
  * async checkpointing + restart-from-latest,
  * a hard node failure at 2/3 progress -> elastic re-code to n-1 workers.
"""

from __future__ import annotations

import argparse
import dataclasses
import shutil
import tempfile
import time


from repro.configs import get_config
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import DeadlineStragglers, FaultInjector
from repro.runtime.faults import FaultPlan
from repro.training import CodedTrainConfig, CodedTrainer


def build_100m(arch: str, d_model: int, layers: int, d_ff: int):
    cfg = get_config(arch)
    pat = len(cfg.block_pattern)
    layers = max((layers // pat) * pat, pat)
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                  d_ff_expert=d_ff // 4)
    cfg = dataclasses.replace(
        cfg, name=f"{cfg.name}-100m", n_layers=layers, d_model=d_model,
        n_heads=8, n_kv=min(cfg.n_kv, 4) if cfg.n_kv < cfg.n_heads else 8,
        d_head=d_model // 8, d_ff=d_ff, vocab=32_000, moe=moe,
        encoder_layers=layers if cfg.encoder_layers else 0,
        frontend_tokens=16 if cfg.frontend != "embed" else 0,
        rnn_width=d_model if cfg.rnn_width else 0,
        local_window=min(cfg.local_window, 256) if cfg.local_window else 0,
        param_dtype="float32", compute_dtype="float32", remat="none",
        vocab_pad_to=256)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-ff", type=int, default=2048)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--code", default="bgc",
                    choices=["frc", "bgc", "rbgc", "sregular", "uncoded"])
    ap.add_argument("--decoder", default="onestep",
                    choices=["onestep", "optimal", "algorithmic", "ignore"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = build_100m(args.arch, args.d_model, args.layers, args.d_ff)
    model = build_model(cfg)
    print(f"arch family: {cfg.name}  params: {model.param_count() / 1e6:.1f}M")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_e2e_")
    tcfg = CodedTrainConfig(
        code=args.code, n_workers=args.workers, s=args.s,
        decoder=args.decoder, seq_len=args.seq_len, steps=args.steps,
        seed=0,
        opt=OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        ckpt_dir=ckpt_dir, ckpt_every=min(50, max(args.steps // 3, 1)),
        keep_last=2, log_every=max(args.steps // 10, 1))

    # Pareto-tail latencies; >1.5s misses the deadline -> straggler
    stragglers = DeadlineStragglers(base=1.0, tail_scale=0.3, alpha=2.0,
                                    deadline=1.5, seed=0)
    # hard node failure at 2/3 progress -> elastic re-code to n-1
    faults = FaultInjector([FaultPlan(step=2 * args.steps // 3,
                                      workers=(args.workers - 1,))])

    trainer = CodedTrainer(model, tcfg, straggler_model=stragglers,
                           fault_injector=faults)
    t0 = time.time()
    out = trainer.run()
    dt = time.time() - t0

    print(f"\n{'step':>6} {'ce':>9} {'stragglers':>10} {'decode_err/k':>12} "
          f"{'workers':>8}")
    for h in out["history"]:
        print(f"{h['step']:>6} {h['mean_ce']:>9.4f} {h['stragglers']:>10} "
              f"{h['decode_err']:>12.4f} {h['n_workers']:>8}")

    first = out["history"][0]["mean_ce"]
    last = out["history"][-1]["mean_ce"]
    print(f"\nce {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({dt:.0f}s wall on CPU); checkpoints in {ckpt_dir}")
    assert last < first, "training must reduce loss"

    # --- restart-from-checkpoint demo -----------------------------------
    print("\nrestart-from-latest-checkpoint (+20 steps):")
    trainer2 = CodedTrainer(model, dataclasses.replace(tcfg, steps=20),
                            straggler_model=stragglers)
    state = trainer2.init_state()
    state, start = trainer2.maybe_restore(state)
    print(f"  restored at step {start}")
    out2 = trainer2.run(state=state, start_step=start, steps=20)
    print(f"  resumed ce={out2['history'][-1]['mean_ce']:.4f}")
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
