"""Quickstart: the paper in 60 seconds on one CPU.

Builds a Bernoulli Gradient Code, knocks out 30% of the workers, decodes
the gradient sum three ways (Algorithms 1/2 + the Lemma-12 iterates), and
shows the decode error the paper bounds — then runs 20 coded training
steps of a tiny LM to show the same machinery driving a real model.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import codes, decoding, theory
from repro.configs import get_config
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import FixedFractionStragglers
from repro.training import CodedTrainConfig, CodedTrainer


def main():
    # ------------------------------------------------------------------
    # 1. the coding-theory core (paper Secs. 2-5)
    # ------------------------------------------------------------------
    k = n = 100          # tasks == workers, as in the paper's simulations
    s = 10               # ~ 2 log k tasks per worker  (Corollary 9 regime)
    delta = 0.3          # 30% stragglers
    rng = np.random.default_rng(0)

    print(f"k={k} tasks, n={n} workers, s={s} tasks/worker, "
          f"delta={delta:.0%} stragglers\n")

    for scheme in ("frc", "bgc", "rbgc"):
        code = codes.make_code(scheme, k=k, n=n, s=s, rng=rng)
        mask = np.ones(n, bool)
        mask[rng.choice(n, int(delta * n), replace=False)] = False
        A, r = code.G[:, mask], int(mask.sum())

        e1 = decoding.err1(A, decoding.default_rho(k, r, s))   # Algorithm 1
        eo = decoding.err(A)                                   # Algorithm 2
        curve = decoding.algorithmic_error_curve(A, iters=6)   # Lemma 12
        print(f"[{scheme:>5}] err1/k={e1 / k:.4f}  err/k={eo / k:.4f}  "
              f"||u_t||^2/k: " +
              " -> ".join(f"{v / k:.3f}" for v in curve[:5]))

    print(f"\nTheorem 5 (FRC, expected one-step error): "
          f"{theory.thm5_expected_err1_frc(k, s, delta):.3f}")
    print(f"Corollary 9: s >= {theory.cor9_s_zero_error(k, delta):.1f} "
          f"gives zero FRC error w.p. >= 1 - 1/k")

    # ------------------------------------------------------------------
    # 2. the same codes driving coded data-parallel LM training
    # ------------------------------------------------------------------
    print("\ncoded training (reduced minicpm-2b, 20 steps, 8 workers, "
          "25% stragglers):")
    cfg = get_config("minicpm-2b", smoke=True)
    model = build_model(cfg)
    trainer = CodedTrainer(
        model,
        CodedTrainConfig(code="bgc", n_workers=8, s=3, decoder="onestep",
                         seq_len=64, steps=20, seed=0,
                         opt=OptConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=20),
                         log_every=5),
        straggler_model=FixedFractionStragglers(delta=0.25, seed=0))
    out = trainer.run()
    for h in out["history"]:
        print(f"  step {h['step']:>3}  ce={h['mean_ce']:.4f}  "
              f"stragglers={h['stragglers']}  decode_err/k={h['decode_err']:.4f}")
    print("\nOK — see examples/coded_training_e2e.py for the full driver.")


if __name__ == "__main__":
    main()
