"""Straggler-robustness study: decode error + modelled wall-clock across
straggler regimes and codes — the paper's runtime/robustness trade-off as
a runnable scenario.

    PYTHONPATH=src python examples/straggler_robustness.py [--trials 200]

Sweeps straggler models (iid / fixed-fraction / Pareto-deadline /
correlated pod-level / adversarial) x codes (FRC / BGC / rBGC) and prints
the mean decode error each combination absorbs, plus the modelled step
time of deadline-vs-sync aggregation.  The adversarial row shows FRC's
Thm-10 collapse while the random codes hold (Sec. 4).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import codes, decoding
from repro.runtime import make_straggler_model
from repro.sim import make_trace, pareto_front, sweep_frontier


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--delta", type=float, default=0.25)
    ap.add_argument("--trials", type=int, default=200)
    args = ap.parse_args(argv)
    n, s, delta = args.n, args.s, args.delta

    scenarios = {
        "iid": dict(name="iid", delta=delta, seed=0),
        "fixed": dict(name="fixed", delta=delta, seed=0),
        "deadline(pareto)": dict(name="deadline", deadline=1.5,
                                 tail_scale=0.4, seed=0),
        "correlated(pod=8)": dict(name="correlated", pod_size=8,
                                  p_pod=0.1, p_node=0.05, seed=0),
        "bimodal(slow-node)": dict(name="bimodal", slow_fraction=0.15,
                                   deadline=1.5, seed=0),
        "adversarial": None,  # built per-code below (needs G)
    }

    print(f"n={n} workers, s={s} tasks/worker, delta~{delta:.0%}; "
          f"{args.trials} steps per cell.  Entries: mean decode err/k "
          f"(one-step | optimal)\n")
    hdr = f"{'straggler model':>18} | " + " | ".join(
        f"{c:^17}" for c in ("frc", "bgc", "rbgc"))
    print(hdr)
    print("-" * len(hdr))

    for sc_name, sc_kw in scenarios.items():
        cells = []
        for scheme in ("frc", "bgc", "rbgc"):
            code = codes.make_code(scheme, k=n, n=n, s=s,
                                   rng=np.random.default_rng(1))
            if sc_name == "adversarial":
                model = make_straggler_model("adversarial", G=code.G,
                                             delta=delta)
            else:
                model = make_straggler_model(**sc_kw)
            e1s, eos = [], []
            for t in range(args.trials):
                mask = model.sample(t, n)
                A = code.G[:, mask]
                r = int(mask.sum())
                e1s.append(decoding.err1(A, decoding.default_rho(n, r, s)) / n)
                eos.append(decoding.err(A) / n)
            cells.append(f"{np.mean(e1s):>7.4f} | {np.mean(eos):>7.4f}")
        print(f"{sc_name:>18} | " + " | ".join(cells))

    # ---- ClusterSim frontier: the trade the paper is buying, measured ----
    trace = make_trace("pareto", steps=args.trials, n=n, deadline=1.5,
                       tail_scale=0.4, seed=0)
    points = sweep_frontier(("frc", "bgc", "rbgc"),
                            ("sync", "deadline", "backup", "adaptive"),
                            trace, s=s)
    print("\nClusterSim frontier (Pareto-tail trace, one batched decode "
          "per cell):")
    print(f"{'scheme':>6} {'policy':>9} | {'step time':>9} "
          f"{'err/k':>7} {'t->target':>9}")
    for p in sorted(points, key=lambda p: (p.policy, p.scheme)):
        print(f"{p.scheme:>6} {p.policy:>9} | {p.mean_step_time:>8.3f}s "
              f"{p.mean_error:>7.4f} {p.time_to_target:>8.1f}s")
    front = pareto_front(points)
    print("pareto front: " + "   ".join(
        f"{p.scheme}/{p.policy} ({p.mean_step_time:.2f}s, "
        f"{p.mean_error:.4f})" for p in front))
    print("=> the paper's trade: bounded step time for a bounded, "
          "decodable gradient error.")


if __name__ == "__main__":
    main()
